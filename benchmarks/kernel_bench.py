"""Kernel microbenchmarks: UB-plan summaries + interpret-mode validation
timings for each Pallas kernel (wall-clock on TPU is out of scope on this
CPU container; the derived columns are the UB-planned VMEM footprints and
grids that determine TPU behavior).

    PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np


def backend_rows(smoke: bool = False) -> list:
    """Generated (plan/emit) kernels vs their baselines, interpret mode:
    hand-written Pallas counterparts, the per-stage (unfused) plan, and the
    fully-unrolled reduction path.  Every row carries the plan's HBM-traffic
    estimate (bytes moved per pipeline invocation) alongside wall-clock —
    cold (plan + emit + first trace + run) *and* warm (the jit-bound
    steady-state the serve path sees).  Returned as dicts so
    ``benchmarks/run.py`` can serialize them to BENCH_backend.json.

    ``smoke=True`` produces just the fast rows (gaussian + matmul timed,
    plus the plan-only lane-carry row) — the CI schema check
    (``scripts/ci.sh --bench-smoke``) regenerates them and diffs their key
    sets against the persisted file to catch stale schema drift without
    paying for the full benchmark."""
    from repro.apps.paper_apps import make_app
    from repro.backend import (
        build_pipeline_plan,
        clear_pipeline_cache,
        compile_pipeline,
        max_abs_error,
    )
    from repro.kernels.matmul import matmul
    from repro.kernels.stencil import stencil3x3

    rng = np.random.default_rng(0)
    rows = []

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        jnp.asarray(out).block_until_ready()
        return out, (time.perf_counter() - t0) * 1e6

    def timed_run(pp, inputs):
        t0 = time.perf_counter()
        got = pp.run(inputs)
        got[pp.pipeline.output].block_until_ready()
        return got, (time.perf_counter() - t0) * 1e6

    def warm_run_us(pp, inputs, reps: int = 3) -> int:
        """Steady-state invocation cost: best of ``reps`` re-runs of an
        already-traced pipeline (jit-bound kernels, no re-trace)."""
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            got = pp.run(inputs)
            got[pp.pipeline.output].block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            best = dt if best is None else min(best, dt)
        return round(best)

    # gaussian 3x3 stencil: generated pipeline vs hand-written stencil3x3
    app = make_app("gaussian")          # 64x64 input tile
    pp = compile_pipeline(app.pipeline)
    inputs = {"input": rng.integers(0, 64, (64, 64)).astype(np.float32)}
    got, gen_us = timed_run(pp, inputs)
    out = got[pp.pipeline.output]
    errs = max_abs_error(pp, inputs, got=got)
    w = jnp.asarray(np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]]) / 16.0, jnp.float32)
    hand, hand_us = timed(
        lambda: stencil3x3(jnp.asarray(inputs["input"]), w, block_h=31, interpret=True)
    )
    vs_hand = float(jnp.max(jnp.abs(jnp.asarray(out) - hand)))
    cs = pp.stage("gaussian")
    rows.append({
        "kernel": "gaussian", "case": "64x64", "baseline": "handwritten",
        "us_generated": round(gen_us), "us_baseline": round(hand_us),
        "us_warm": warm_run_us(pp, inputs),
        "max_err_ref": max(errs.values()), "max_err_vs_baseline": vs_hand,
        "grid": list(cs.grid), "vmem_kib": cs.plan.vmem_bytes // 1024,
        "hbm_kib": pp.plan.hbm_bytes() // 1024, "hbm_kib_baseline": None,
    })

    # matmul tile: generated pipeline vs hand-written Pallas matmul
    m, n, k = 64, 64, 32
    app = make_app("matmul", m=m, n=n, k=k)
    pp = compile_pipeline(app.pipeline)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    out, gen_us = timed(lambda: pp({"A": a, "B": b}))
    err_ref = float(np.max(np.abs(np.asarray(out) - a @ b)))
    hand, hand_us = timed(
        lambda: matmul(jnp.asarray(a), jnp.asarray(b), block_m=32, block_n=32,
                       block_k=32, interpret=True)
    )
    vs_hand = float(jnp.max(jnp.abs(jnp.asarray(out) - hand)))
    cs = pp.stage("matmul")
    rows.append({
        "kernel": "matmul", "case": f"{m}x{n}x{k}", "baseline": "handwritten",
        "us_generated": round(gen_us), "us_baseline": round(hand_us),
        "us_warm": warm_run_us(pp, {"A": a, "B": b}),
        "max_err_ref": err_ref, "max_err_vs_baseline": vs_hand,
        "grid": list(cs.grid), "vmem_kib": cs.plan.vmem_bytes // 1024,
        "hbm_kib": pp.plan.hbm_bytes() // 1024, "hbm_kib_baseline": None,
    })

    # lane×carry composition: a wide gaussian lane-blocked at bw=128
    # carries its column rings across lane steps, so each input row is
    # fetched once per row sweep instead of once per tap per lane block —
    # the recompute twin at the same blocking re-reads the lane halo for
    # every lane step.  Plan-only columns (eval_rows is the FLOP proxy,
    # hbm_kib the traffic); cheap enough to sit in the smoke set so
    # --bench-smoke schema-checks the row
    app = make_app("gaussian", size=33, width=255)
    carry = build_pipeline_plan(app.pipeline, block_w=128)   # auto: carries
    rec = build_pipeline_plan(app.pipeline, block_w=128, line_buffer=False)
    kg_c = carry.kernels[0]
    rows.append({
        "kernel": "gaussian_lane_carry", "case": "33x255",
        "baseline": "lane-recompute",
        "us_generated": None, "us_baseline": None,
        "max_err_ref": None, "max_err_vs_baseline": None,
        "grid": list(kg_c.grid), "bw": kg_c.bw,
        "lane_carry": kg_c.notes.get("lane_carry"),
        "lane_rings": sum(
            1 for kg in carry.kernels for r in kg.rings if r.lane
        ),
        "vmem_kib": kg_c.vmem_bytes // 1024,
        "hbm_kib": carry.hbm_bytes() // 1024,
        "hbm_kib_baseline": rec.hbm_bytes() // 1024,
        "eval_rows": carry.total_eval_rows(),
        "eval_rows_baseline": rec.total_eval_rows(),
    })

    if smoke:
        return rows

    # fused cascades vs the per-stage (HBM round-trip) plan
    for name, kw, case in [
        ("unsharp", {}, "64x64-cascade"),
        ("harris", {"schedule": "sch3", "size": 36}, "32x32-cascade"),
    ]:
        app = make_app(name, **kw)
        pp_f = compile_pipeline(app.pipeline)
        pp_u = compile_pipeline(app.pipeline, fuse=False)
        inputs = {
            nm: rng.integers(0, 64, s).astype(np.float32)
            for nm, s in app.input_extents.items()
        }
        got_f, fused_us = timed_run(pp_f, inputs)
        _, unfused_us = timed_run(pp_u, inputs)
        errs = max_abs_error(pp_f, inputs, got=got_f)
        rows.append({
            "kernel": f"{name}_fused", "case": case, "baseline": "unfused",
            "us_generated": round(fused_us), "us_baseline": round(unfused_us),
            "max_err_ref": max(errs.values()), "max_err_vs_baseline": None,
            "grid": [list(ck.grid) for ck in pp_f.kernels],
            "vmem_kib": sum(ck.plan.vmem_bytes for ck in pp_f.kernels) // 1024,
            "hbm_kib": pp_f.plan.hbm_bytes() // 1024,
            "hbm_kib_baseline": pp_u.plan.hbm_bytes() // 1024,
            "kernels": pp_f.plan.n_kernels, "stages": pp_f.plan.n_stages,
        })

    # cross-grid-step line buffers vs recompute fusion, under the *auto*
    # arbitration (the default plan): carried intermediates / ring
    # deliveries wherever the scheduler cost model keeps them — camera's
    # stride-2 demosaic parity ring is priced out by its serial rotation
    # and declined, which is what fixed the old camera_linebuf regression
    # (ring delivery slower than its recompute baseline).  eval_rows is the
    # FLOP proxy (stage rows evaluated per invocation), hbm_kib the
    # traffic; us_warm columns are the steady-state (jit-bound) serve cost,
    # where the carry plans win
    for name, kw, case in [
        ("unsharp", {}, "64x64-cascade"),
        ("harris", {"schedule": "sch3", "size": 36}, "32x32-cascade"),
        ("camera", {"size": 16}, "32x32-isp"),
        ("gaussian", {}, "64x64-stencil"),
    ]:
        app = make_app(name, **kw)
        pp_lb = compile_pipeline(app.pipeline)          # auto arbitration
        pp_rc = compile_pipeline(app.pipeline, line_buffer=False)
        inputs = {
            nm: rng.integers(0, 64, s).astype(np.float32)
            for nm, s in app.input_extents.items()
        }
        got_lb, lb_us = timed_run(pp_lb, inputs)
        got_rc, rc_us = timed_run(pp_rc, inputs)
        errs = max_abs_error(pp_lb, inputs, got=got_lb)
        vs_rc = float(np.max(np.abs(
            np.asarray(got_lb[pp_lb.pipeline.output])
            - np.asarray(got_rc[pp_rc.pipeline.output])
        )))
        rows.append({
            "kernel": f"{name}_linebuf", "case": case,
            "baseline": "recompute-fusion",
            "us_generated": round(lb_us), "us_baseline": round(rc_us),
            "us_warm": warm_run_us(pp_lb, inputs),
            "us_warm_baseline": warm_run_us(pp_rc, inputs),
            "max_err_ref": max(errs.values()), "max_err_vs_baseline": vs_rc,
            "grid": [list(ck.grid) for ck in pp_lb.kernels],
            "vmem_kib": sum(ck.plan.vmem_bytes for ck in pp_lb.kernels) // 1024,
            "hbm_kib": pp_lb.plan.hbm_bytes() // 1024,
            "hbm_kib_baseline": pp_rc.plan.hbm_bytes() // 1024,
            "eval_rows": pp_lb.plan.total_eval_rows(),
            "eval_rows_baseline": pp_rc.plan.total_eval_rows(),
            "linebuf": sorted(
                nm for ns in pp_lb.plan.line_buffered.values() for nm in ns
            ),
            "rings": pp_lb.plan.n_rings,
            "kernels": pp_lb.plan.n_kernels, "stages": pp_lb.plan.n_stages,
        })

    # grid-level reduction vs full in-kernel unrolling (large-K matmul)
    m, n, k = 16, 16, 512
    app = make_app("matmul", m=m, n=n, k=k)
    pp_g = compile_pipeline(app.pipeline)            # K=512 >= threshold
    pp_u = compile_pipeline(app.pipeline, grid_reduction=False)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    out_g, grid_us = timed(lambda: pp_g({"A": a, "B": b}))
    _, unrolled_us = timed(lambda: pp_u({"A": a, "B": b}))
    err_ref = float(np.max(np.abs(
        np.asarray(out_g) - a.astype(np.float64) @ b.astype(np.float64)
    )))
    ck = pp_g.kernels[0]
    rows.append({
        "kernel": "matmul_gridred", "case": f"{m}x{n}x{k}", "baseline": "unrolled",
        "us_generated": round(grid_us), "us_baseline": round(unrolled_us),
        "max_err_ref": err_ref, "max_err_vs_baseline": None,
        "grid": list(ck.grid), "vmem_kib": ck.plan.vmem_bytes // 1024,
        "hbm_kib": pp_g.plan.hbm_bytes() // 1024,
        "hbm_kib_baseline": pp_u.plan.hbm_bytes() // 1024,
        "red_chunk": ck.red_grid.chunk if ck.red_grid else None,
    })

    # resident broadcast operand vs per-panel chunk refetch (the README
    # "Known limits" bug): B stays whole in VMEM, fetched once, instead of
    # re-walking its chunk sequence on every row panel.  pp_g above is the
    # resident plan already (red_resident defaults on), so only the
    # refetch twin needs building
    pp_ref = compile_pipeline(app.pipeline, red_resident=False)   # refetch
    _, ref_us = timed(lambda: pp_ref({"A": a, "B": b}))
    rows.append({
        "kernel": "matmul_gridred_resident", "case": f"{m}x{n}x{k}",
        "baseline": "chunk-refetch",
        "us_generated": round(grid_us), "us_baseline": round(ref_us),
        "max_err_ref": err_ref, "max_err_vs_baseline": None,
        "grid": list(ck.grid), "vmem_kib": ck.plan.vmem_bytes // 1024,
        "hbm_kib": pp_g.plan.hbm_bytes() // 1024,
        "hbm_kib_baseline": pp_ref.plan.hbm_bytes() // 1024,
        "resident": [g.buffer for g in ck.groups if g.resident],
    })

    # plan-keyed pipeline cache: cold = plan + emit + first trace + run;
    # warm = cache hit (no re-plan, no re-emit) + jit-warm kernels.  The
    # acceptance bar is warm >= 10x faster than cold — in practice it is
    # orders of magnitude (the serve path's repeat-invocation cost)
    for name, kw, case in [
        ("unsharp", {}, "64x64-cascade"),
        ("matmul", {"m": 16, "n": 16, "k": 512}, "16x16x512"),
    ]:
        app = make_app(name, **kw)
        inputs = {
            nm: rng.integers(0, 16, s).astype(np.float32)
            for nm, s in app.input_extents.items()
        }
        clear_pipeline_cache()
        t0 = time.perf_counter()
        pp_c = compile_pipeline(app.pipeline, cache=True)
        got = pp_c.run(inputs)
        got[pp_c.pipeline.output].block_until_ready()
        cold_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        pp_w = compile_pipeline(app.pipeline, cache=True)
        got_w = pp_w.run(inputs)
        got_w[pp_w.pipeline.output].block_until_ready()
        warm_us = (time.perf_counter() - t0) * 1e6
        clear_pipeline_cache()
        rows.append({
            "kernel": f"{name}_cache", "case": case,
            "baseline": "cold-plan+trace",
            "us_generated": round(warm_us), "us_baseline": round(cold_us),
            "us_warm": round(warm_us), "us_cold": round(cold_us),
            "warm_speedup": round(cold_us / max(warm_us, 1.0), 1),
            "cache_hit": pp_w is pp_c,
            "max_err_ref": None, "max_err_vs_baseline": 0.0,
            "grid": [list(ck.grid) for ck in pp_c.kernels],
            "vmem_kib": sum(ck.plan.vmem_bytes for ck in pp_c.kernels) // 1024,
            "hbm_kib": pp_c.plan.hbm_bytes() // 1024,
            "hbm_kib_baseline": None,
        })

    # lane-blocked planning on wide extents: a 64x2048 tile under a 48 KiB
    # VMEM budget is infeasible for the flat planner (even a one-row
    # full-width panel overflows); the 2-D lane grid plans it with a
    # 128-multiple lane block and lands the estimate under budget.  Plan
    # columns only — the point of this row is the planner's footprint
    # arithmetic on shapes the interpret path cannot afford to run in CI
    budget = 48 * 1024
    app = make_app("gaussian", size=64, width=2048)
    flat = build_pipeline_plan(app.pipeline, vmem_budget=budget,
                               lane_block=False)
    lane = build_pipeline_plan(app.pipeline, vmem_budget=budget)
    kg_f, kg_l = flat.kernels[0], lane.kernels[0]
    rows.append({
        "kernel": "gaussian_lane_wide", "case": "64x2048",
        "baseline": "full-width-resident",
        "us_generated": None, "us_baseline": None,
        "max_err_ref": None, "max_err_vs_baseline": None,
        "grid": list(kg_l.grid), "bw": kg_l.bw,
        "vmem_kib": kg_l.vmem_bytes // 1024,
        "vmem_kib_baseline": kg_f.vmem_bytes // 1024,
        "vmem_budget_kib": budget // 1024,
        "fits_budget": kg_l.vmem_bytes <= budget,
        "baseline_fits_budget": kg_f.vmem_bytes <= budget,
        "hbm_kib": lane.hbm_bytes() // 1024,
        "hbm_kib_baseline": flat.hbm_bytes() // 1024,
    })
    return rows


def main() -> None:
    from repro.core.ubplan import plan_attention, plan_matmul, plan_ssd, plan_stencil
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.matmul import matmul
    from repro.kernels.ssd import ssd_scan
    from repro.kernels.stencil import stencil3x3

    rng = np.random.default_rng(0)
    print("kernel,case,us_per_call_interp,max_err,grid,vmem_kib")

    # matmul
    for m, n, k in [(128, 128, 128), (256, 256, 256)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        t0 = time.perf_counter()
        got = matmul(a, b, block_m=64, block_n=64, block_k=64, interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(got - ref.matmul_ref(a, b))))
        plan = plan_matmul(m, n, k, 4)
        print(f"matmul,{m}x{n}x{k},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # stencil
    for h, w in [(64, 64), (128, 128)]:
        x = jnp.asarray(rng.standard_normal((h + 2, w + 2)), jnp.float32)
        wts = jnp.asarray(np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]]) / 16.0, jnp.float32)
        t0 = time.perf_counter()
        got = stencil3x3(x, wts, block_h=32, interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(got - ref.stencil3x3_ref(x, wts))))
        plan = plan_stencil(h, w, 1)
        print(f"stencil3x3,{h}x{w},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # flash attention
    for b, s, d in [(2, 256, 64)]:
        q = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        t0 = time.perf_counter()
        got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(
            got - ref.attention_ref(q, k, v, causal=True)
        )))
        plan = plan_attention(s, s, d, 4)
        print(f"flash_attention,b{b}s{s}d{d},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # SSD
    s_, h_, p_, n_ = 128, 4, 16, 32
    x = jnp.asarray(rng.standard_normal((s_, h_, p_)), jnp.float32)
    dtv = jnp.asarray(np.abs(rng.standard_normal((s_, h_))) * 0.1 + 0.01, jnp.float32)
    av = jnp.asarray(-np.abs(rng.standard_normal(h_)) - 0.1, jnp.float32)
    bv = jnp.asarray(rng.standard_normal((s_, n_)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((s_, n_)), jnp.float32)
    t0 = time.perf_counter()
    got = ssd_scan(x, dtv, av, bv, cv, chunk=32, interpret=True)
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(got - ref.ssd_ref(x, dtv, av, bv, cv))))
    plan = plan_ssd(s_, h_, p_, n_)
    print(f"ssd,s{s_}h{h_}p{p_}n{n_},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # generated backend kernels vs baselines (hand-written / unfused /
    # recompute-fusion / unrolled / chunk-refetch / cold-cache / full-width)
    print()
    print(
        "kernel,case,baseline,us_generated,us_baseline,us_warm,"
        "max_err_ref,max_err_vs_baseline,grid,vmem_kib,hbm_kib,"
        "hbm_kib_baseline,eval_rows,eval_rows_baseline"
    )

    def fmt(v, spec=""):
        return "-" if v is None else (f"{v:{spec}}" if spec else str(v))

    for r in backend_rows():
        print(
            f"backend_{r['kernel']},{r['case']},{r['baseline']},"
            f"{fmt(r['us_generated'])},{fmt(r['us_baseline'])},"
            f"{fmt(r.get('us_warm'))},"
            f"{fmt(r['max_err_ref'], '.2e')},"
            f"{fmt(r['max_err_vs_baseline'], '.2e')},"
            f"\"{r['grid']}\",{r['vmem_kib']},{r['hbm_kib']},"
            f"{fmt(r.get('hbm_kib_baseline'))},"
            f"{fmt(r.get('eval_rows'))},{fmt(r.get('eval_rows_baseline'))}"
        )


if __name__ == "__main__":
    main()
