"""Kernel microbenchmarks: UB-plan summaries + interpret-mode validation
timings for each Pallas kernel (wall-clock on TPU is out of scope on this
CPU container; the derived columns are the UB-planned VMEM footprints and
grids that determine TPU behavior).

    PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np


def backend_rows() -> list:
    """Generated (Stage->Pallas codegen) kernels vs their hand-written
    counterparts, interpret mode.  Returned as dicts so ``benchmarks/run.py``
    can serialize them to BENCH_backend.json."""
    from repro.apps.paper_apps import make_app
    from repro.backend import compile_pipeline, max_abs_error
    from repro.kernels.matmul import matmul
    from repro.kernels.stencil import stencil3x3

    rng = np.random.default_rng(0)
    rows = []

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        jnp.asarray(out).block_until_ready()
        return out, (time.perf_counter() - t0) * 1e6

    def timed_run(pp, inputs):
        t0 = time.perf_counter()
        got = pp.run(inputs)
        got[pp.pipeline.output].block_until_ready()
        return got, (time.perf_counter() - t0) * 1e6

    # gaussian 3x3 stencil: generated pipeline vs hand-written stencil3x3
    app = make_app("gaussian")          # 64x64 input tile
    pp = compile_pipeline(app.pipeline)
    inputs = {"input": rng.integers(0, 64, (64, 64)).astype(np.float32)}
    got, gen_us = timed_run(pp, inputs)
    out = got[pp.pipeline.output]
    errs = max_abs_error(pp, inputs, got=got)
    w = jnp.asarray(np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]]) / 16.0, jnp.float32)
    hand, hand_us = timed(
        lambda: stencil3x3(jnp.asarray(inputs["input"]), w, block_h=31, interpret=True)
    )
    vs_hand = float(jnp.max(jnp.abs(jnp.asarray(out) - hand)))
    cs = pp.stage("gaussian")
    rows.append({
        "kernel": "gaussian", "case": "64x64",
        "us_generated": round(gen_us), "us_handwritten": round(hand_us),
        "max_err_ref": max(errs.values()), "max_err_vs_hand": vs_hand,
        "grid": list(cs.grid), "vmem_kib": cs.plan.vmem_bytes // 1024,
    })

    # matmul tile: generated pipeline vs hand-written Pallas matmul
    m, n, k = 64, 64, 32
    app = make_app("matmul", m=m, n=n, k=k)
    pp = compile_pipeline(app.pipeline)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    out, gen_us = timed(lambda: pp({"A": a, "B": b}))
    err_ref = float(np.max(np.abs(np.asarray(out) - a @ b)))
    hand, hand_us = timed(
        lambda: matmul(jnp.asarray(a), jnp.asarray(b), block_m=32, block_n=32,
                       block_k=32, interpret=True)
    )
    vs_hand = float(jnp.max(jnp.abs(jnp.asarray(out) - hand)))
    cs = pp.stage("matmul")
    rows.append({
        "kernel": "matmul", "case": f"{m}x{n}x{k}",
        "us_generated": round(gen_us), "us_handwritten": round(hand_us),
        "max_err_ref": err_ref, "max_err_vs_hand": vs_hand,
        "grid": list(cs.grid), "vmem_kib": cs.plan.vmem_bytes // 1024,
    })

    # cascade pipeline (no hand-written counterpart): generated only
    app = make_app("unsharp")
    pp = compile_pipeline(app.pipeline)
    inputs = {"input": rng.integers(0, 64, (64, 64)).astype(np.float32)}
    got, gen_us = timed_run(pp, inputs)
    errs = max_abs_error(pp, inputs, got=got)
    rows.append({
        "kernel": "unsharp", "case": "64x64-cascade",
        "us_generated": round(gen_us), "us_handwritten": None,
        "max_err_ref": max(errs.values()), "max_err_vs_hand": None,
        "grid": [list(cs.grid) for cs in pp.stages],
        "vmem_kib": sum(cs.plan.vmem_bytes for cs in pp.stages) // 1024,
    })
    return rows


def main() -> None:
    from repro.core.ubplan import plan_attention, plan_matmul, plan_ssd, plan_stencil
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.matmul import matmul
    from repro.kernels.ssd import ssd_scan
    from repro.kernels.stencil import stencil3x3

    rng = np.random.default_rng(0)
    print("kernel,case,us_per_call_interp,max_err,grid,vmem_kib")

    # matmul
    for m, n, k in [(128, 128, 128), (256, 256, 256)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        t0 = time.perf_counter()
        got = matmul(a, b, block_m=64, block_n=64, block_k=64, interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(got - ref.matmul_ref(a, b))))
        plan = plan_matmul(m, n, k, 4)
        print(f"matmul,{m}x{n}x{k},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # stencil
    for h, w in [(64, 64), (128, 128)]:
        x = jnp.asarray(rng.standard_normal((h + 2, w + 2)), jnp.float32)
        wts = jnp.asarray(np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]]) / 16.0, jnp.float32)
        t0 = time.perf_counter()
        got = stencil3x3(x, wts, block_h=32, interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(got - ref.stencil3x3_ref(x, wts))))
        plan = plan_stencil(h, w, 1)
        print(f"stencil3x3,{h}x{w},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # flash attention
    for b, s, d in [(2, 256, 64)]:
        q = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        t0 = time.perf_counter()
        got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              interpret=True)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(
            got - ref.attention_ref(q, k, v, causal=True)
        )))
        plan = plan_attention(s, s, d, 4)
        print(f"flash_attention,b{b}s{s}d{d},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # SSD
    s_, h_, p_, n_ = 128, 4, 16, 32
    x = jnp.asarray(rng.standard_normal((s_, h_, p_)), jnp.float32)
    dtv = jnp.asarray(np.abs(rng.standard_normal((s_, h_))) * 0.1 + 0.01, jnp.float32)
    av = jnp.asarray(-np.abs(rng.standard_normal(h_)) - 0.1, jnp.float32)
    bv = jnp.asarray(rng.standard_normal((s_, n_)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((s_, n_)), jnp.float32)
    t0 = time.perf_counter()
    got = ssd_scan(x, dtv, av, bv, cv, chunk=32, interpret=True)
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(got - ref.ssd_ref(x, dtv, av, bv, cv))))
    plan = plan_ssd(s_, h_, p_, n_)
    print(f"ssd,s{s_}h{h_}p{p_}n{n_},{dt:.0f},{err:.2e},{plan.grid},{plan.vmem_bytes//1024}")

    # generated backend kernels: hand-written vs codegen throughput
    print()
    print("kernel,case,us_generated,us_handwritten,max_err_ref,max_err_vs_hand,grid,vmem_kib")
    for r in backend_rows():
        hand = r["us_handwritten"] if r["us_handwritten"] is not None else "-"
        vs = f"{r['max_err_vs_hand']:.2e}" if r["max_err_vs_hand"] is not None else "-"
        print(
            f"backend_{r['kernel']},{r['case']},{r['us_generated']},{hand},"
            f"{r['max_err_ref']:.2e},{vs},\"{r['grid']}\",{r['vmem_kib']}"
        )


if __name__ == "__main__":
    main()
