"""Serve-path throughput: images/sec for batched pipeline dispatch.

Measures the serve bridge (``backend.serve_bridge.PipelineServer``) against
the per-tile loop it replaces: the same tile stream served one
``pallas_call`` sweep per batch versus one call per tile.  Interpret mode
on this CPU container, so the absolute numbers are dispatch-overhead
stories, not TPU wall-clock — but the *ratio* is exactly the per-call
overhead amortization the batch grid dimension buys, and the cold-vs-warm
split shows what the plan cache saves a serving process.

    PYTHONPATH=src python -m benchmarks.serve_bench            # full rows
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke    # schema check

Rows persist under the ``"serve"`` key of BENCH_backend.json (written by
``python -m benchmarks.run``); ``--smoke`` regenerates cheap rows and
diffs their key sets against the persisted file, mirroring the
``--bench-smoke`` stale-schema guard for the kernel rows.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# (app name, app kwargs, compile kwargs, batch slots): tiles are small on
# purpose — serving amortizes per-call dispatch overhead, which tiny tiles
# make visible; one fused stencil cascade and one DNN matmul tile
SERVE_CASES = [
    ("unsharp", dict(size=16), dict(fuse=True, block_h=8), 16),
    ("matmul", dict(m=16, n=16, k=16), dict(), 16),
]

# fraction of the degraded-mode stream that is marker-poisoned: the SLO
# question the degraded row answers is "what does serving look like with
# a few percent bad tiles", not "with a hostile majority"
DEGRADED_FRAC = 0.05


def _best_of(fn, reps: int):
    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def serve_rows(smoke: bool = False) -> list:
    """One row per serve case: warm images/sec for the per-tile loop and
    the batched bridge, cold (compile + first dispatch) images/sec, the
    warm speedup, a bit-exactness bit (batched outputs vs the per-tile
    loop, ragged final dispatch included), the bridge's cache/dispatch
    counters, and the **degraded-mode** throughput — the same stream with
    ``DEGRADED_FRAC`` of its tiles marker-poisoned, served through
    quarantine bisection (poisoned tiles fail closed with
    ``PoisonedTileError``, healthy tiles stay bit-exact) — the price of
    fault isolation in images/sec.  ``smoke=True`` keeps the same schema
    but a single timing rep per measurement."""
    from repro.apps.paper_apps import make_app
    from repro.backend import (
        PipelineServer,
        PoisonedTileError,
        clear_pipeline_cache,
        compile_pipeline,
        pipeline_cache_stats,
    )
    from repro.backend.faults import mark_poison, poison_output

    reps = 1 if smoke else 5
    rng = np.random.default_rng(0)
    rows = []
    for name, akw, ckw, slots in SERVE_CASES:
        app = make_app(name, **akw)
        out_name = app.pipeline.output
        innames = list(app.input_extents)
        # steady-state timing on full batches; the ragged tail (a drain-time
        # case, not a throughput case) is exercised by the bit-exact check
        n_tiles = 2 * slots
        tiles = [
            {
                n: rng.standard_normal(
                    tuple(app.input_extents[n])
                ).astype(np.float32)
                for n in innames
            }
            for _ in range(n_tiles + 3)
        ]
        timed_tiles = tiles[:n_tiles]

        # -- per-tile loop baseline (warm: pipeline already traced) --------
        ptp = compile_pipeline(app.pipeline, **ckw)
        loop_out = [np.asarray(ptp.run(t)[out_name]) for t in tiles]  # warm
        t_loop = _best_of(
            lambda: [np.asarray(ptp.run(t)[out_name]) for t in timed_tiles],
            reps,
        )

        # -- batched bridge: cold = fresh cache, server build + first full
        # dispatch (plan + emit + trace); warm = steady-state dispatches --
        # (reset_stats: the per-case cache counters recorded in the row
        # below must start from zero, not accumulate across cases)
        clear_pipeline_cache(reset_stats=True)
        t0 = time.perf_counter()
        srv = PipelineServer(app.pipeline, batch_slots=slots, **ckw)
        for t in tiles[:slots]:
            srv.submit(t)
        srv.step()
        t_cold = time.perf_counter() - t0

        done = srv.run(tiles)  # incl. one ragged final dispatch
        bit_exact = all(
            np.array_equal(r.outputs[out_name], ref)
            for r, ref in zip(done, loop_out)
        )
        t_batch = _best_of(lambda: srv.run(timed_tiles), reps)
        stats = srv.stats()

        # -- degraded mode: the same stream with DEGRADED_FRAC of its tiles
        # marker-poisoned; every timed run pays the quarantine bisection
        # that isolates them, and the correctness pass asserts poisoned
        # tiles fail closed while healthy tiles match the per-tile loop
        # byte-for-byte
        n_bad = max(1, int(round(DEGRADED_FRAC * n_tiles)))
        bad_idx = sorted(
            int(i)
            for i in np.random.default_rng(1).choice(
                n_tiles, size=n_bad, replace=False
            )
        )
        degraded_tiles = [dict(t) for t in timed_tiles]  # arrays shared
        for i in bad_idx:
            mark_poison(degraded_tiles[i])
        with poison_output(srv):
            done_deg = srv.run(degraded_tiles)
            healthy_exact = all(
                np.array_equal(r.outputs[out_name], loop_out[i])
                for i, r in enumerate(done_deg)
                if i not in bad_idx
            )
            failed_closed = all(
                isinstance(done_deg[i].error, PoisonedTileError)
                for i in bad_idx
            )
            t_degraded = _best_of(lambda: srv.run(degraded_tiles), reps)
        deg_stats = srv.stats()

        rows.append({
            "kernel": name,
            "case": "x".join(
                str(e) for e in app.input_extents[innames[0]]
            ),
            "batch_slots": slots,
            "tiles": len(tiles),
            "images_sec_loop": round(n_tiles / t_loop, 1),
            "images_sec_batched_warm": round(n_tiles / t_batch, 1),
            "images_sec_batched_cold": round(slots / t_cold, 1),
            "speedup_warm": round(t_loop / t_batch, 2),
            "bit_exact": bool(bit_exact),
            "dispatches": stats["dispatches"],
            "cache_hits": stats["hits"],
            "cache_misses": stats["misses"],
            "cache_entries": stats["entries"],
            "degraded_frac": round(n_bad / n_tiles, 3),
            "images_sec_degraded_warm": round(n_tiles / t_degraded, 1),
            "degraded_vs_clean": round(t_batch / t_degraded, 2),
            "poisoned_failed_closed": bool(failed_closed),
            "healthy_bit_exact": bool(healthy_exact),
            "quarantine_dispatches": deg_stats["quarantine_dispatches"],
        })
    return rows


def serve_smoke_check(path: str | None = None) -> int:
    """``--smoke``: regenerate cheap serve rows and diff their key sets
    against the ``"serve"`` rows persisted in BENCH_backend.json."""
    import json

    if path is None:
        path = os.path.join(
            os.path.dirname(__file__), "..", "BENCH_backend.json"
        )
    with open(path) as f:
        persisted = {
            r["kernel"]: r for r in json.load(f).get("serve", [])
        }
    problems = []
    fresh = serve_rows(smoke=True)
    for row in fresh:
        old = persisted.get(row["kernel"])
        if old is None:
            problems.append(
                f"{row['kernel']}: serve row missing from "
                f"{os.path.normpath(path)}"
            )
            continue
        missing = sorted(set(row) - set(old))
        stale = sorted(set(old) - set(row))
        if missing or stale:
            problems.append(
                f"{row['kernel']}: serve schema drift — persisted lacks "
                f"{missing or '-'}, persisted has stale {stale or '-'}"
            )
        if not row["bit_exact"]:
            problems.append(
                f"{row['kernel']}: batched serve outputs diverged from the "
                f"per-tile loop"
            )
        if not row["healthy_bit_exact"]:
            problems.append(
                f"{row['kernel']}: degraded-mode healthy tiles diverged "
                f"from the per-tile loop (quarantine leaked a poisoned "
                f"dispatch)"
            )
        if not row["poisoned_failed_closed"]:
            problems.append(
                f"{row['kernel']}: a poisoned tile did not fail closed "
                f"with PoisonedTileError"
            )
    for p in problems:
        print(f"serve-smoke: {p}", file=sys.stderr)
    if problems:
        print(
            "serve-smoke: regenerate with `python -m benchmarks.run`",
            file=sys.stderr,
        )
        return 1
    print(f"serve-smoke: {len(fresh)} serve rows match the persisted schema")
    return 0


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        sys.exit(serve_smoke_check())
    print(
        "kernel,case,batch_slots,tiles,images_sec_loop,"
        "images_sec_batched_warm,images_sec_batched_cold,speedup_warm,"
        "bit_exact,images_sec_degraded_warm,degraded_vs_clean,"
        "healthy_bit_exact"
    )
    for r in serve_rows():
        print(
            f"{r['kernel']},{r['case']},{r['batch_slots']},{r['tiles']},"
            f"{r['images_sec_loop']},{r['images_sec_batched_warm']},"
            f"{r['images_sec_batched_cold']},{r['speedup_warm']},"
            f"{r['bit_exact']},{r['images_sec_degraded_warm']},"
            f"{r['degraded_vs_clean']},{r['healthy_bit_exact']}"
        )
    print("# persist into BENCH_backend.json with `python -m benchmarks.run`")


if __name__ == "__main__":
    main()
