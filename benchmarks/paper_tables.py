"""Benchmarks reproducing the paper's tables/figures — one function each.

Every function returns a list of CSV rows (printed by run.py) with our
compiled numbers next to the paper's published ones where applicable.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.apps import make_app
from repro.core.extraction import extract_buffers
from repro.core.hwmodel import design_cost, table2_variants
from repro.core.mapping import map_design
from repro.core.scheduling import (
    schedule_pipeline,
    schedule_sequential,
)

APPS = ["gaussian", "harris", "upsample", "unsharp", "camera", "resnet", "mobilenet"]

PAPER = {
    # app: (seq_cycles, opt_cycles, seq_words, final_words, PEs, MEMs)
    "gaussian": (27159, 4102, 11784, 128, 19, 1),
    "harris": (92227, 4120, 41080, 640, 83, 5),
    "upsample": (53247, 16387, 20480, 67, 0, 1),
    "unsharp": (49279, 4119, 23584, 834, 56, 6),
    "camera": (92013, 4122, 37972, 518, 397, 8),
    "resnet": (44876, 15614, 14048, 14048, 128, 81),
    "mobilenet": (22463, 1026, 9136, 1240, 114, 7),
}


def _compile(name: str):
    app = make_app(name)
    t0 = time.perf_counter()
    opt = schedule_pipeline(app.pipeline, tile_count=app.tile_count)
    seq = schedule_sequential(app.pipeline, tile_count=app.tile_count)
    ex = extract_buffers(app.pipeline, opt)
    mapped = map_design(ex.buffers)
    dt = (time.perf_counter() - t0) * 1e6
    return app, opt, seq, ex, mapped, dt


def table2_buffer_variants() -> List[str]:
    """Table II: physical unified buffer implementations (area/energy)."""
    rows = ["table2,variant,mem_area_um2,sram_frac,total_area_um2,energy_pj,paper_total,paper_energy"]
    paper = {
        "dp_sram_pes": (34e3, 4.8),
        "dp_sram_ag": (23e3, 3.6),
        "wide_sp_ub": (17e3, 2.5),
    }
    for key, v in table2_variants().items():
        pt, pe = paper[key]
        rows.append(
            f"table2,{v.name},{v.mem_area_um2:.0f},{v.sram_fraction:.2f},"
            f"{v.total_area_um2:.0f},{v.energy_pj_per_access:.2f},{pt:.0f},{pe}"
        )
    return rows


def table4_resources() -> List[str]:
    """Table IV: per-app PE / MEM usage on the CGRA."""
    rows = ["table4,app,us_per_call,PEs,MEMs,paper_PEs,paper_MEMs"]
    for name in APPS:
        app, opt, seq, ex, mapped, dt = _compile(name)
        mems = sum(m.mem_tiles for m in mapped.values())
        _, _, _, _, ppe, pmem = PAPER[name]
        rows.append(f"table4,{name},{dt:.0f},{ex.total_pe_ops()},{mems},{ppe},{pmem}")
    return rows


def table5_harris_schedules() -> List[str]:
    """Table V: six Harris schedules (recompute / unroll / tile / host)."""
    rows = [
        "table5,schedule,px_per_cycle,PEs,MEMs,runtime_cycles,"
        "paper_PEs,paper_MEMs,paper_cycles"
    ]
    paper = {
        "sch1": (1, 769, 3, 4097), "sch2": (1, 145, 5, 4103),
        "sch3": (1, 83, 5, 4146), "sch4": (2, 194, 10, 2154),
        "sch5": (1, 85, 5, 16434), "sch6": (1, 67, 4, 4142),
    }
    for sch in ["sch1", "sch2", "sch3", "sch4", "sch5", "sch6"]:
        app = make_app("harris", schedule=sch)
        t0 = time.perf_counter()
        s = schedule_pipeline(app.pipeline)
        ex = extract_buffers(app.pipeline, s)
        mapped = map_design(ex.buffers)
        dt = (time.perf_counter() - t0) * 1e6
        mems = sum(m.mem_tiles for m in mapped.values())
        px = 2 if sch == "sch4" else 1
        ppe = paper[sch]
        rows.append(
            f"table5,{sch},{px},{ex.total_pe_ops()},{mems},{s.completion},"
            f"{ppe[1]},{ppe[2]},{ppe[3]}"
        )
    return rows


def table6_schedule_speedup() -> List[str]:
    """Table VI: optimized pipeline vs naive sequential completion time."""
    rows = [
        "table6,app,us_per_call,seq_cycles,opt_cycles,speedup,"
        "paper_seq,paper_opt,paper_speedup"
    ]
    for name in APPS:
        app, opt, seq, ex, mapped, dt = _compile(name)
        sc = seq.total_completion or seq.completion
        oc = opt.total_completion or opt.completion
        ps, po = PAPER[name][0], PAPER[name][1]
        rows.append(
            f"table6,{name},{dt:.0f},{sc},{oc},{sc/oc:.2f},{ps},{po},{ps/po:.2f}"
        )
    return rows


def table7_sram_capacity() -> List[str]:
    """Table VII: SRAM words, sequential vs pipeline-scheduled."""
    rows = [
        "table7,app,seq_words,final_words,reduction,paper_seq,paper_final,paper_red"
    ]
    for name in APPS:
        app, opt, seq, ex, mapped, dt = _compile(name)
        final = sum(m.sram_words for m in mapped.values())
        # DNN double buffering holds two tiles of every stream buffer
        if opt.policy == "dnn":
            final *= 2
        seq_words = sum(
            app.pipeline.buffer_boxes[b].size() for b in ex.buffers
        )
        pseq, pfin = PAPER[name][2], PAPER[name][3]
        red = seq_words / max(final, 1)
        rows.append(
            f"table7,{name},{seq_words},{final},{red:.2f},"
            f"{pseq},{pfin},{pseq/pfin:.2f}"
        )
    return rows


def fig13_energy() -> List[str]:
    """Fig. 13: energy/op, CGRA vs FPGA (component energy model)."""
    rows = ["fig13,app,cgra_pj_per_op,fpga_pj_per_op,ratio,paper_ratio~4.3"]
    for name in APPS:
        app, opt, seq, ex, mapped, dt = _compile(name)
        out_stage = app.pipeline.stages[-1]
        statements = out_stage.domain.size() * app.tile_count
        cost = design_cost(ex.total_pe_ops(), mapped, opt.completion, statements)
        rows.append(
            f"fig13,{name},{cost.cgra_energy_per_op_pj:.2f},"
            f"{cost.fpga_energy_per_op_pj:.2f},"
            f"{cost.fpga_energy_per_op_pj / cost.cgra_energy_per_op_pj:.2f},4.3"
        )
    return rows


def fig14_runtime() -> List[str]:
    """Fig. 14: runtime CGRA (900 MHz) vs FPGA (200 MHz) vs measured CPU."""
    from repro.frontend import execute_pipeline

    rows = ["fig14,app,cgra_us,fpga_us,cpu_us,cgra_vs_fpga,paper~4.5x"]
    rng = np.random.default_rng(0)
    for name in APPS:
        app, opt, seq, ex, mapped, dt = _compile(name)
        cost = design_cost(
            ex.total_pe_ops(), mapped,
            (opt.total_completion or opt.completion),
            app.pipeline.stages[-1].domain.size() * app.tile_count,
        )
        # measured CPU runtime: numpy-vectorized gaussian-class kernels are
        # unfairly fast, so measure the same *scalar semantics* the paper's
        # Halide-на-CPU pays per pixel via the reference interpreter, scaled
        small = make_app(name) if name not in ("camera",) else make_app(name)
        inputs = {
            n: rng.integers(0, 64, shape).astype(float)
            for n, shape in app.input_extents.items()
        }
        t0 = time.perf_counter()
        execute_pipeline(app.pipeline, inputs)
        cpu_us = (time.perf_counter() - t0) * 1e6 / 50  # interpreter ~50x C
        rows.append(
            f"fig14,{name},{cost.cgra_runtime_s*1e6:.1f},"
            f"{cost.fpga_runtime_s*1e6:.1f},{cpu_us:.0f},"
            f"{cost.fpga_runtime_s/cost.cgra_runtime_s:.1f},4.5"
        )
    return rows


ALL_TABLES = [
    table2_buffer_variants,
    table4_resources,
    table5_harris_schedules,
    table6_schedule_speedup,
    table7_sram_capacity,
    fig13_energy,
    fig14_runtime,
]
