"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows.  Roofline/dry-run numbers
live in results/dryrun (produced by ``repro.launch.dryrun``) and are
summarized by ``python -m benchmarks.roofline_table``.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    for fn in ALL_TABLES:
        print(f"# --- {fn.__name__}: {fn.__doc__.strip().splitlines()[0]}")
        for row in fn():
            print(row)
        print()

    write_backend_bench()


def write_backend_bench(path: str | None = None) -> str:
    """Benchmark the generated backend kernels and persist BENCH_backend.json."""
    import json

    from benchmarks.kernel_bench import backend_rows

    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..", "BENCH_backend.json")
    rows = backend_rows()
    with open(path, "w") as f:
        json.dump({"generated_kernels": rows}, f, indent=2)
    print(f"# wrote {os.path.normpath(path)} ({len(rows)} generated-kernel entries)")
    return path


if __name__ == "__main__":
    main()
