"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows.  Roofline/dry-run numbers
live in results/dryrun (produced by ``repro.launch.dryrun``) and are
summarized by ``python -m benchmarks.roofline_table``.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    if "--bench-smoke" in sys.argv[1:]:
        sys.exit(bench_smoke_check())
    if "--tune-smoke" in sys.argv[1:]:
        from benchmarks.tune_bench import tune_smoke_check

        sys.exit(tune_smoke_check())

    from benchmarks.paper_tables import ALL_TABLES

    for fn in ALL_TABLES:
        print(f"# --- {fn.__name__}: {fn.__doc__.strip().splitlines()[0]}")
        for row in fn():
            print(row)
        print()

    write_backend_bench()


def write_backend_bench(path: str | None = None) -> str:
    """Benchmark the generated backend kernels, the serve bridge, and the
    schedule autotuner, and persist BENCH_backend.json
    (``generated_kernels`` + ``serve`` + ``tune`` keys).  The tune pass
    also refreshes the repo schedule db (``schedule_db.json``) — the
    winners ``compile_pipeline(tune="auto")`` serves."""
    import json

    from benchmarks.kernel_bench import backend_rows
    from benchmarks.serve_bench import serve_rows
    from benchmarks.tune_bench import tune_rows

    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..", "BENCH_backend.json")
    rows = backend_rows()
    srows = serve_rows()
    trows = tune_rows()
    with open(path, "w") as f:
        json.dump(
            {"generated_kernels": rows, "serve": srows, "tune": trows},
            f, indent=2,
        )
    print(
        f"# wrote {os.path.normpath(path)} ({len(rows)} generated-kernel "
        f"entries, {len(srows)} serve entries, {len(trows)} tune entries)"
    )
    return path


def bench_smoke_check(path: str | None = None) -> int:
    """``--bench-smoke``: regenerate the two fast benchmark rows (gaussian +
    matmul) and diff their key sets against the rows persisted in
    BENCH_backend.json.  A benchmark-schema change that was not
    re-persisted (stale-schema drift) fails here — in seconds, instead of
    being discovered after a full benchmark run or, worse, shipping a JSON
    whose columns no longer match the code that wrote it."""
    import json

    from benchmarks.kernel_bench import backend_rows

    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..", "BENCH_backend.json")
    with open(path) as f:
        persisted = {r["kernel"]: r for r in json.load(f)["generated_kernels"]}
    problems = []
    fresh = backend_rows(smoke=True)
    for row in fresh:
        old = persisted.get(row["kernel"])
        if old is None:
            problems.append(
                f"{row['kernel']}: row missing from {os.path.normpath(path)} "
                f"(benchmark gained a row that was never persisted)"
            )
            continue
        missing = sorted(set(row) - set(old))
        stale = sorted(set(old) - set(row))
        if missing or stale:
            problems.append(
                f"{row['kernel']}: schema drift vs persisted row — "
                f"persisted lacks {missing or '-'}, "
                f"persisted has stale {stale or '-'}"
            )
    for p in problems:
        print(f"bench-smoke: {p}", file=sys.stderr)
    if problems:
        print(
            "bench-smoke: regenerate with `python -m benchmarks.run`",
            file=sys.stderr,
        )
        return 1
    print(f"bench-smoke: {len(fresh)} rows match the persisted schema")
    return 0


if __name__ == "__main__":
    main()
