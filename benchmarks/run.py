"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows.  Roofline/dry-run numbers
live in results/dryrun (produced by ``repro.launch.dryrun``) and are
summarized by ``python -m benchmarks.roofline_table``.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    for fn in ALL_TABLES:
        print(f"# --- {fn.__name__}: {fn.__doc__.strip().splitlines()[0]}")
        for row in fn():
            print(row)
        print()


if __name__ == "__main__":
    main()
