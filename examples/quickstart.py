"""Quickstart: compile a Halide-style stencil through the full unified-buffer
pipeline, validate it on three backends, and show the TPU mapping.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.apps import make_app
from repro.core.extraction import extract_buffers
from repro.core.mapping import map_design
from repro.core.scheduling import schedule_pipeline, schedule_sequential
from repro.core.simulator import validate_against_reference, validate_mapped_buffers
from repro.core.ubplan import plan_stencil
from repro.frontend import execute_pipeline


def main() -> None:
    # 1. the app: gaussian 3x3 over a 64x64 input tile (paper Fig. 1 class)
    app = make_app("gaussian")
    print(f"app: {app.name} — {app.description}")
    print(f"stages: {[s.name for s in app.pipeline.stages]}")

    # 2. cycle-accurate schedule (paper §V-B)
    sched = schedule_pipeline(app.pipeline)
    seq = schedule_sequential(app.pipeline)
    print(f"policy={sched.policy}  completion={sched.completion} cycles "
          f"(naive sequential: {seq.completion}; paper: 4102 vs 27159)")

    # 3. unified buffers (paper §III) + mapping (paper §V-C)
    ex = extract_buffers(app.pipeline, sched)
    for name, ub in ex.buffers.items():
        print(f"buffer {name}: {len(ub.in_ports)} in / {len(ub.out_ports)} out "
              f"ports, capacity bound {ub.capacity_bound()} words")
    mapped = map_design(ex.buffers)
    for name, mb in mapped.items():
        print(f"mapped {name}: {len(mb.sr_taps)} SR taps, "
              f"{mb.mem_tiles} MEM tile(s), {mb.sram_words} SRAM words")

    # 4. validate: cycle-accurate simulation == reference interpreter
    small = make_app("gaussian", size=16)
    ssched = schedule_pipeline(small.pipeline)
    rng = np.random.default_rng(0)
    inputs = {n: rng.integers(0, 64, s).astype(float)
              for n, s in small.input_extents.items()}
    problems = validate_against_reference(small.pipeline, ssched, inputs)
    sex = extract_buffers(small.pipeline, ssched)
    problems += validate_mapped_buffers(sex, map_design(sex.buffers))
    print(f"simulation vs reference: {'OK' if not problems else problems}")

    # 5. the TPU retargeting: same stencil as a UB-planned Pallas kernel
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.stencil import stencil3x3

    plan = plan_stencil(62, 62, halo=1)
    print(f"pallas plan: grid={plan.grid}, vmem={plan.vmem_bytes/1024:.0f} KiB "
          f"across {len(plan.streams)} streams")
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    w = jnp.asarray(np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]]) / 16.0, jnp.float32)
    got = stencil3x3(x, w, interpret=True)
    want = ref.stencil3x3_ref(x, w)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"pallas kernel vs oracle: max abs err {err:.2e}")


if __name__ == "__main__":
    main()
