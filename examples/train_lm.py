"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU.

Uses the full production stack — data pipeline with prefetch, microbatched
train step with remat, AdamW, checkpoint/restore — on a custom ~100M config
(a scaled-down tinyllama shape that still exercises every code path).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.train import (
    AdamWConfig,
    DataPipeline,
    TrainState,
    adamw_init,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: 12 layers, d_model 640, vocab 32000 (tied embeddings)
    cfg = dataclasses.replace(
        get_config("tinyllama_1_1b"),
        name="llama-100m",
        n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
        head_dim=64, d_ff=2560, vocab=32000,
    )
    n = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1.5e-3, warmup_steps=20)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, microbatches=2,
                        kv_chunk=64, remat=True),
        donate_argnums=(0,),
    )
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = TrainState(params, adamw_init(params), jax.random.PRNGKey(1))
    data = DataPipeline(cfg.vocab, args.batch, args.seq, seed=0)

    losses = []
    t0 = time.time()
    try:
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if step % 20 == 0 or step == args.steps - 1:
                tput = args.batch * args.seq * (step + 1) / (time.time() - t0)
                print(f"[train_lm] step {step:4d}  loss {losses[-1]:7.4f}  "
                      f"{tput/1e3:6.1f}k tok/s")
    finally:
        data.close()
    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.2 else 'check convergence'})")


if __name__ == "__main__":
    main()
