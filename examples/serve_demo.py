"""Batched serving demo: greedy decoding with a KV cache on a reduced model,
then the pipeline serve bridge's failure paths — a poisoned submission, a
quarantined tile, a deadline miss, and a backpressure rejection — each
failing closed with its named ``backend.errors`` class while every healthy
request drains bit-exact.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    cfg = get_config("tinyllama_1_1b").reduced(n_layers=4, d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=4, max_seq=64)

    rng = jax.random.PRNGKey(7)
    prompts = jax.random.randint(rng, (4, 8), 0, cfg.vocab)
    reqs = [Request(prompt=[int(t) for t in prompts[i]], max_new=24)
            for i in range(4)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    for i, r in enumerate(done):
        print(f"[serve] req{i}: {r.prompt[:4]}... -> {r.generated[:12]}...")
    total = sum(len(r.generated) for r in done)
    print(f"[serve] {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s)")

    # determinism check: greedy decode twice gives identical streams
    engine2 = ServeEngine(cfg, params, batch_slots=4, max_seq=64)
    reqs2 = [Request(prompt=[int(t) for t in prompts[i]], max_new=24)
             for i in range(4)]
    done2 = engine2.run(reqs2)
    same = all(a.generated == b.generated for a, b in zip(done, done2))
    print(f"[serve] deterministic: {same}")

    failure_paths()


def failure_paths() -> None:
    """The fault-tolerance contract, live: every failure below is *named*
    (a ``backend.errors`` class printed with its ``[CODE]``), no failure
    touches anyone else's request, and the healthy tiles that drain
    alongside are bit-equal to the per-tile pipeline."""
    from repro.apps.paper_apps import make_app
    from repro.backend import (
        NonFiniteInputError,
        PipelineServer,
        QueueFullError,
        compile_pipeline,
    )
    from repro.backend.faults import FaultClock, mark_poison, poison_output

    print("\n[faults] pipeline serve bridge failure paths")
    app = make_app("gaussian", size=13)
    rng = np.random.default_rng(11)
    shape = tuple(app.pipeline.buffer_boxes["input"].extents)
    tiles = [
        {"input": rng.integers(0, 16, shape).astype(np.float32)}
        for _ in range(6)
    ]
    clock = FaultClock()
    srv = PipelineServer(
        app.pipeline, batch_slots=4, block_h=4,
        max_pending=4, admission="reject", clock=clock,
    )

    # 1. a NaN submission is rejected at the door — never queued
    poisoned = {"input": tiles[0]["input"].copy()}
    poisoned["input"][3, 3] = np.nan
    try:
        srv.submit(poisoned)
    except NonFiniteInputError as e:
        print(f"[faults] submit rejected: {e}")

    # 2. a finite-but-poisoned tile (models a data-dependent kernel bug)
    # is isolated by quarantine bisection; its batch neighbours still serve
    marked = mark_poison({"input": tiles[1]["input"].copy()})
    with poison_output(srv):
        done = srv.run([tiles[0], marked, tiles[2]])
    print(f"[faults] quarantined: {done[1].error}")

    # 3. a deadline shorter than the queue wait fails closed, late results
    # are discarded — the deterministic clock makes this reproducible
    late = srv.submit(tiles[3], deadline=0.5)
    clock.advance(2.0)
    srv.step()
    print(f"[faults] deadline: {late.error}")

    # 4. a full bounded queue rejects new work by name
    for t in tiles[2:6]:
        srv.submit(t)
    try:
        srv.submit(tiles[0])
    except QueueFullError as e:
        print(f"[faults] backpressure: {e}")
    while srv.pending:
        srv.step()

    # healthy requests were never disturbed: bit-exact vs per-tile compile
    ref = compile_pipeline(app.pipeline, block_h=4)
    out = app.pipeline.output
    exact = all(
        np.array_equal(r.outputs[out], np.asarray(ref.run(t)[out]))
        for r, t in ((done[0], tiles[0]), (done[2], tiles[2]))
    )
    s = srv.stats()
    print(
        f"[faults] healthy tiles bit-exact: {exact}; counters: "
        f"poisoned={s['poisoned_tiles']} deadline={s['deadline_misses']} "
        f"rejected={s['validation_rejects']}+{s['backpressure_rejects']} "
        f"served={s['served']} failed={s['failed']}"
    )


if __name__ == "__main__":
    main()
