"""Batched serving demo: greedy decoding with a KV cache on a reduced model.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    cfg = get_config("tinyllama_1_1b").reduced(n_layers=4, d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=4, max_seq=64)

    rng = jax.random.PRNGKey(7)
    prompts = jax.random.randint(rng, (4, 8), 0, cfg.vocab)
    reqs = [Request(prompt=[int(t) for t in prompts[i]], max_new=24)
            for i in range(4)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    for i, r in enumerate(done):
        print(f"[serve] req{i}: {r.prompt[:4]}... -> {r.generated[:12]}...")
    total = sum(len(r.generated) for r in done)
    print(f"[serve] {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s)")

    # determinism check: greedy decode twice gives identical streams
    engine2 = ServeEngine(cfg, params, batch_slots=4, max_seq=64)
    reqs2 = [Request(prompt=[int(t) for t in prompts[i]], max_new=24)
             for i in range(4)]
    done2 = engine2.run(reqs2)
    same = all(a.generated == b.generated for a, b in zip(done, done2))
    print(f"[serve] deterministic: {same}")


if __name__ == "__main__":
    main()
