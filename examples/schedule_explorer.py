"""Schedule exploration (paper §VI-C, Table V): trade throughput for area by
changing only Halide-style scheduling directives.

    PYTHONPATH=src python examples/schedule_explorer.py
"""

import sys

sys.path.insert(0, "src")

from repro.apps import make_app
from repro.core.extraction import extract_buffers
from repro.core.mapping import map_design
from repro.core.scheduling import schedule_pipeline

DESCRIPTIONS = {
    "sch1": "recompute all intermediates (everything inlined)",
    "sch2": "recompute some (buffer the gradients only)",
    "sch3": "no recompute (buffer every stage)",
    "sch4": "unroll by 2 (two output pixels per cycle)",
    "sch5": "2x larger tile in each dimension",
    "sch6": "last stage on the host CPU",
}


def main() -> None:
    print(f"{'schedule':8s} {'pixels/cyc':>10s} {'PEs':>6s} {'MEMs':>5s} "
          f"{'cycles':>7s}  description")
    for sch in ["sch1", "sch2", "sch3", "sch4", "sch5", "sch6"]:
        app = make_app("harris", schedule=sch)
        s = schedule_pipeline(app.pipeline)
        ex = extract_buffers(app.pipeline, s)
        mapped = map_design(ex.buffers)
        mems = sum(m.mem_tiles for m in mapped.values())
        px = 2 if sch == "sch4" else 1
        print(f"{sch:8s} {px:>10d} {ex.total_pe_ops():>6d} {mems:>5d} "
              f"{s.completion:>7d}  {DESCRIPTIONS[sch]}")
    print("\n(compare paper Table V: the same trade-offs, driven purely by "
          "scheduling directives)")


if __name__ == "__main__":
    main()
