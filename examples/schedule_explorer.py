"""Schedule autotuner CLI (and the paper §VI-C Table V comparison).

Default mode runs the verifier-gated autotuner (``backend/autotune``) over
a set of apps: enumerate candidate schedules — joint (bh, bw) pairs,
fusion cut, line-buffer mode, reduction chunk — prune with the scheduler
cycle model, certify every survivor with ``verify_plan`` before it is
emitted or measured, time the certified survivors through the plan-keyed
compile cache, and persist each winner in the JSON schedule database that
``compile_pipeline(tune="auto")`` consults.

    PYTHONPATH=src python examples/schedule_explorer.py
    PYTHONPATH=src python examples/schedule_explorer.py \
        --apps harris,unsharp,matmul --db schedule_db.json
    PYTHONPATH=src python examples/schedule_explorer.py --no-measure
    PYTHONPATH=src python examples/schedule_explorer.py --table-v

``--table-v`` prints the original paper Table V exploration (throughput /
PE / MEM trade-offs on harris driven purely by scheduling directives).
"""

import argparse
import json
import sys

sys.path.insert(0, "src")

# the autotunable app set: (name, make_app kwargs, case label)
TUNE_APPS = {
    "harris": ({"schedule": "sch3", "size": 20}, "20x20"),
    "unsharp": ({"size": 18}, "18x18"),
    "matmul": ({"m": 16, "n": 16, "k": 2048}, "16x16x2048"),
    "gaussian": ({"size": 18}, "18x18"),
    "camera": ({"size": 16}, "16x16"),
}

DESCRIPTIONS = {
    "sch1": "recompute all intermediates (everything inlined)",
    "sch2": "recompute some (buffer the gradients only)",
    "sch3": "no recompute (buffer every stage)",
    "sch4": "unroll by 2 (two output pixels per cycle)",
    "sch5": "2x larger tile in each dimension",
    "sch6": "last stage on the host CPU",
}


def table_v() -> None:
    """The paper Table V comparison this script originally printed."""
    from repro.apps import make_app
    from repro.core.extraction import extract_buffers
    from repro.core.mapping import map_design
    from repro.core.scheduling import schedule_pipeline

    print(f"{'schedule':8s} {'pixels/cyc':>10s} {'PEs':>6s} {'MEMs':>5s} "
          f"{'cycles':>7s}  description")
    for sch in ["sch1", "sch2", "sch3", "sch4", "sch5", "sch6"]:
        app = make_app("harris", schedule=sch)
        s = schedule_pipeline(app.pipeline)
        ex = extract_buffers(app.pipeline, s)
        mapped = map_design(ex.buffers)
        mems = sum(m.mem_tiles for m in mapped.values())
        px = 2 if sch == "sch4" else 1
        print(f"{sch:8s} {px:>10d} {ex.total_pe_ops():>6d} {mems:>5d} "
              f"{s.completion:>7d}  {DESCRIPTIONS[sch]}")
    print("\n(compare paper Table V: the same trade-offs, driven purely by "
          "scheduling directives)")


def tune(args) -> int:
    from repro.apps import make_app
    from repro.backend.autotune import default_db_path, search

    names = args.apps.split(",")
    unknown = sorted(set(names) - set(TUNE_APPS))
    if unknown:
        raise SystemExit(
            f"unknown app(s) {unknown}; choose from {sorted(TUNE_APPS)}"
        )
    db = None if args.no_db else (args.db or default_db_path())
    print(
        f"{'app':10s} {'case':>12s} {'cands':>5s} {'meas':>4s} {'rej':>3s} "
        f"{'heur_us':>9s} {'tuned_us':>9s} {'speedup':>7s}  winning schedule"
    )
    ok = True
    for name in names:
        kw, case = TUNE_APPS[name]
        app = make_app(name, **kw)
        r = search(
            app.pipeline, label=name, db=db,
            max_candidates=args.max_candidates, measure_top=args.top,
            measure=not args.no_measure, reps=args.reps, seed=args.seed,
            log=(lambda m: print(f"# {m}", file=sys.stderr))
            if args.verbose else None,
        )
        sched = json.dumps(r.schedule) if r.schedule else "{} (heuristic)"
        if args.no_measure:
            print(f"{name:10s} {case:>12s} {len(r.candidates):>5d} "
                  f"{'-':>4s} {len(r.rejected):>3d} {'-':>9s} {'-':>9s} "
                  f"{'-':>7s}  {sched} "
                  f"(model: {r.model_cycles and round(r.model_cycles)} vs "
                  f"{r.heuristic_model_cycles and round(r.heuristic_model_cycles)} cyc)")
            continue
        if r.warm_us > r.heuristic_warm_us:
            ok = False                  # structurally impossible; fail loudly
        print(f"{name:10s} {case:>12s} {len(r.candidates):>5d} "
              f"{len(r.measured):>4d} {len(r.rejected):>3d} "
              f"{r.heuristic_warm_us:>9.1f} {r.warm_us:>9.1f} "
              f"{r.speedup:>6.2f}x  {sched}")
    if db is not None:
        print(f"# schedule db: {db}", file=sys.stderr)
    if not ok:
        print("schedule_explorer: a stored winner measured slower than the "
              "heuristic plan (should be structurally impossible — the "
              "heuristic is always a measured candidate)", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table-v", action="store_true",
                    help="print the paper Table V scheduling comparison")
    ap.add_argument("--apps", default="harris,unsharp,matmul",
                    help=f"comma-separated subset of {sorted(TUNE_APPS)}")
    ap.add_argument("--db", default=None,
                    help="schedule db path (default: repo schedule_db.json)")
    ap.add_argument("--no-db", action="store_true",
                    help="search without persisting winners")
    ap.add_argument("--no-measure", action="store_true",
                    help="model-only search (deterministic; nothing executed)")
    ap.add_argument("--max-candidates", type=int, default=32)
    ap.add_argument("--top", type=int, default=8,
                    help="certified candidates to measure per app")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true",
                    help="log pruned/rejected candidates to stderr")
    args = ap.parse_args(argv)
    if args.table_v:
        table_v()
        return 0
    return tune(args)


if __name__ == "__main__":
    sys.exit(main())
